"""The two-plugin VOL architecture (paper §4.1, Fig. 2).

``GlobalVOL`` is the client-side plugin: it intercepts dataset-level
calls (create/write/read/query), decomposes them into per-object
sub-requests using the ObjectMap, scatter/gathers against the store, and
performs *global* optimizations (object pruning via zone maps, parallel
dispatch, decomposable-op pushdown planning).

Read/query sub-requests flow through ``ObjectStore.exec_batch`` — one
batched objclass request per primary OSD — so fabric ops scale with the
number of OSDs, not the number of objects.  Planning consults an
epoch-keyed client-side zone-map cache instead of issuing one xattr
lookup per (object x filter) per query; the cache is invalidated (a)
wholesale whenever the cluster-map epoch bumps (failure / resize — the
acting sets and surviving xattrs may have changed), and (b) per object
when this client rewrites it (``write`` refreshes the object's zone
map).  Same-epoch rewrites by *other* clients are not observed (no
cross-client coherence protocol); multi-writer deployments need an
xattr version tag — see ROADMAP "Open items".

``LocalVOL`` is the storage-side plugin: it decides the *physical*
representation of each object (layout row/col, per-column codec) from
local information, executes objclass pipelines, and adapts layout to the
observed workload ("physical design management", paper §5) — all without
the client or the access library knowing (independent evolution, goal 3).
"""

from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Mapping

import numpy as np

from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core.logical import (
    LogicalDataset, RowRange, concat_tables, validate_table)
from repro.core.partition import (
    ObjectMap, PartitionPolicy, objmap_key, plan_partition)
from repro.core.store import ObjectStore


# --------------------------------------------------------------------------
# LocalVOL — storage-side physical design
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LocalVOL:
    """Per-deployment physical design policy.

    ``codec_for`` picks a per-column codec from the column's value range —
    e.g. token ids bitpack to ceil(log2(vocab)) bits (2-3x over int32).
    ``access_stats`` counts column-scan vs row-fetch requests; when scans
    dominate, stored row-layout objects are transformed to columnar
    (online physical design transformation).
    """

    default_layout: str = "col"
    bitpack_ints: bool = True
    scan_to_row_threshold: float = 0.75
    access_stats: dict[str, int] = dataclasses.field(
        default_factory=lambda: {"scan": 0, "fetch": 0})

    def codecs_for(self, table: Mapping[str, np.ndarray]) -> dict[str, str]:
        out = {}
        for k, a in table.items():
            a = np.asarray(a)
            if (self.bitpack_ints and np.issubdtype(a.dtype, np.integer)
                    and a.size and int(a.min()) >= 0):
                bits = fmt.bitpack_width(int(a.max()))
                if bits <= 24:  # else bitpack loses to raw int32
                    out[k] = f"bitpack{bits}"
        return out

    def encode(self, table: Mapping[str, np.ndarray]) -> bytes:
        layout = self.default_layout
        codecs = self.codecs_for(table) if layout == "col" else {}
        return fmt.encode_block(table, layout=layout, codecs=codecs)

    def note_access(self, kind: str) -> None:
        self.access_stats[kind] = self.access_stats.get(kind, 0) + 1

    def preferred_layout(self) -> str:
        s, f = self.access_stats["scan"], self.access_stats["fetch"]
        if s + f == 0:
            return self.default_layout
        return "col" if s / (s + f) >= (1 - self.scan_to_row_threshold) \
            else "row"


# --------------------------------------------------------------------------
# GlobalVOL — client-side decompose / scatter / gather
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReadPlan:
    """The decomposition of one logical request into object sub-requests."""

    sub_requests: tuple            # ((obj_name, local RowRange), ...)
    pruned: tuple                  # object names skipped via zone maps
    pushdown: bool                 # ops run storage-side?


class GlobalVOL:
    def __init__(self, store: ObjectStore, *,
                 local: LocalVOL | None = None, workers: int = 8):
        self.store = store
        self.local = local or LocalVOL()
        self.workers = workers
        # client-side zone-map cache, keyed by cluster-map epoch: one
        # xattr lookup per object per epoch instead of one per
        # (object x filter) per query
        self._zm_cache: dict[str, dict] = {}
        self._zm_epoch: int = -1

    def _pin_epoch(self) -> None:
        """Invalidate the zone-map cache if the cluster map moved; pin
        it to the current epoch (the single invalidation rule, shared by
        the read side and by cache-on-write)."""
        epoch = self.store.cluster.epoch
        if epoch != self._zm_epoch:  # failure/resize: invalidate all
            self._zm_cache.clear()
            self._zm_epoch = epoch

    def _zone_map(self, name: str) -> dict:
        self._pin_epoch()
        zm = self._zm_cache.get(name)
        if zm is None:
            zm = self.store.xattr(name).get("zone_map", {})
            self._zm_cache[name] = zm
        return zm

    # ------------------------------------------------------------ create
    def create(self, ds: LogicalDataset,
               policy: PartitionPolicy = PartitionPolicy()) -> ObjectMap:
        """Plan the dataset->object mapping and persist it to the store."""
        omap = plan_partition(ds, policy)
        self.store.put(objmap_key(ds.name), omap.to_bytes())
        return omap

    def open(self, dataset_name: str) -> ObjectMap:
        return ObjectMap.from_bytes(self.store.get(objmap_key(dataset_name)))

    # ------------------------------------------------------------ write
    def write(self, omap: ObjectMap, table: Mapping[str, np.ndarray],
              *, rows: RowRange | None = None, workers: int | None = None,
              forwarding: bool = True) -> int:
        """Scatter a row range to its objects (parallel writers).

        ``forwarding=False`` bypasses the plugin machinery and writes one
        native blob — the paper's Table-1 native-HDF5 baseline.
        Returns bytes written (client->store).
        """
        ds = omap.dataset
        rows = rows or RowRange(0, ds.n_rows)
        validate_table(ds, table, rows)
        if not forwarding:
            # native access-library path: the app serializes once and
            # writes its LOCAL store — no forwarding hop, no replication
            blob = self.local.encode(dict(table))
            name = f"{ds.name}/native"
            self.store.osds[self.store.cluster.primary(name)].put(name,
                                                                  blob)
            return len(blob)

        subs = omap.lookup(rows)
        # pin the cache to the current epoch so the zone maps we are
        # about to cache-on-write survive the first read-side lookup
        self._pin_epoch()

        def write_one(sub) -> int:
            extent, local_rows = sub
            glob = local_rows.shift(extent.row_start)
            part = {k: np.asarray(v)[glob.start - rows.start:
                                     glob.stop - rows.start]
                    for k, v in table.items()}
            blob = self.local.encode(part)
            zm = fmt.zone_map(part)
            self.store.put(extent.name, blob,
                           xattr={"zone_map": zm,
                                  "rows": [glob.start, glob.stop]})
            self._zm_cache[extent.name] = zm  # keep the cache fresh
            return len(blob)

        w = workers or self.workers
        if w <= 1:
            return sum(write_one(s) for s in subs)
        with ThreadPoolExecutor(max_workers=w) as pool:
            return sum(pool.map(write_one, subs))

    # ------------------------------------------------------------ read
    def read(self, omap: ObjectMap, rows: RowRange,
             columns: list[str] | None = None) -> dict[str, np.ndarray]:
        """Gather a row range; per-object select+project run storage-side
        so only requested rows/columns move.  The per-object pipelines go
        out as one batched request per OSD (``exec_batch``)."""
        subs = omap.lookup(rows)
        names, pipelines = [], []
        for extent, local in subs:
            pipeline = [oc.op("select", rows=(local.start, local.stop))]
            if columns is not None:
                pipeline.append(oc.op("project", cols=list(columns)))
            names.append(extent.name)
            pipelines.append(pipeline)
        blobs = self.store.exec_batch(names, pipelines)
        for _ in names:
            self.local.note_access("fetch")
        return concat_tables([fmt.decode_block(b) for b in blobs])

    # ------------------------------------------------------------ query
    def plan(self, omap: ObjectMap, ops: list[oc.ObjOp]) -> ReadPlan:
        """Global optimization: prune objects whose zone maps cannot match
        a leading filter; decide pushdown vs gather."""
        pushdown = oc.pipeline_decomposable(ops)
        prunable = [o for o in ops if o.name == "filter"]
        keep, pruned = [], []
        for extent in omap:
            skip = False
            if prunable:  # one cached zone-map fetch per object
                zm = self._zone_map(extent.name)
                for f in prunable:
                    rng = zm.get(f.params["col"])
                    if rng and _prunable(rng, f.params["cmp"],
                                         f.params["value"]):
                        skip = True
                        break
            (pruned if skip else keep).append(extent.name)
        return ReadPlan(tuple((k, None) for k in keep), tuple(pruned),
                        pushdown)

    def query(self, omap: ObjectMap, ops: list[oc.ObjOp],
              *, allow_approx: bool = False) -> tuple[Any, dict]:
        """Execute an op pipeline over the whole dataset.

        Decomposable pipelines push down: each object runs the pipeline on
        its OSD, partials combine client-side.  Holistic tails (median)
        gather their projected input instead — unless ``allow_approx``
        rewrites them to the decomposable sketch (paper §3.2).
        Returns (result, stats).
        """
        ops = list(ops)
        rewritten = False
        if ops and ops[-1].name == "median" and allow_approx:
            col = ops[-1].params["col"]
            lo, hi = self._column_bounds(omap, col)
            ops[-1] = oc.op("quantile_sketch", col=col, lo=lo, hi=hi)
            rewritten = True

        plan = self.plan(omap, ops)
        names = [n for n, _ in plan.sub_requests]
        before = self.store.fabric.snapshot()
        tail = oc.get_impl(ops[-1].name) if ops else None

        if ops and not tail.table_out and tail.combine is not None:
            partials = self.store.exec_batch(names, ops)
            for _ in names:
                self.local.note_access("scan")
            result = oc.combine_partials(ops, partials)
        elif ops and not tail.table_out:  # holistic: gather projected input
            proj = [oc.op(o.name, **o.params) for o in ops[:-1]]
            col = ops[-1].params["col"]
            proj.append(oc.op("project", cols=[col]))
            blobs = self.store.exec_batch(names, proj)
            cols = [fmt.decode_block(b) for b in blobs]
            result = oc.median_exact(
                [{col: c[col].ravel()} for c in cols], col)
        else:  # table-out pipeline: gather result tables
            blobs = self.store.exec_batch(names, ops)
            result = concat_tables([fmt.decode_block(b) for b in blobs])

        after = self.store.fabric.snapshot()
        stats = {k: after[k] - before[k] for k in after}
        stats.update(objects_touched=len(names),
                     objects_pruned=len(plan.pruned),
                     pushdown=plan.pushdown, approx_rewrite=rewritten)
        return result, stats

    # ------------------------------------------------------------ helpers
    def _column_bounds(self, omap: ObjectMap, col: str) -> tuple[float, float]:
        lo, hi = np.inf, -np.inf
        for extent in omap:
            zm = self._zone_map(extent.name)
            if col in zm:
                lo, hi = min(lo, zm[col][0]), max(hi, zm[col][1])
        if not np.isfinite(lo):
            lo, hi = 0.0, 1.0
        return float(lo), float(hi) + 1e-9


def _prunable(rng: list, cmp: str, value: float) -> bool:
    lo, hi = rng
    if cmp == "<":
        return lo >= value
    if cmp == "<=":
        return lo > value
    if cmp == ">":
        return hi <= value
    if cmp == ">=":
        return hi < value
    if cmp == "==":
        return value < lo or value > hi
    return False
