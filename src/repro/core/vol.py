"""The two-plugin VOL architecture (paper §4.1, Fig. 2).

``GlobalVOL`` is the client-side plugin: it intercepts dataset-level
calls (create/write/read/query), decomposes them into per-object
sub-requests using the ObjectMap, and hands every read-side request to
the ONE scan engine (``core.scan``): ``read`` compiles to a row-range
``PhysicalPlan``, ``query`` compiles a raw objclass pipeline, and
``scan`` exposes the fluent builder (``vol.scan("ds").filter(...)
.agg(...).execute()``).  The engine — not this module — decides the
prune strategy and execution class; the VOL contributes the global
metadata the engine compiles against (ObjectMap, zone-map cache,
column bounds for the approx-median rewrite).

Every interaction rides the store's symmetric per-OSD batch plane:
writes go through ``ObjectStore.put_batch`` (one request per primary
OSD — windowed/streaming when transfers take simulated time, so the
per-object encode overlaps the NIC stream); compiled plans execute
through the streaming consume of ``exec_combine`` (aggregate tails:
one partial per OSD), ``exec_concat`` (table-out tails: ONE framed
table response per OSD, decoded frame-by-frame as they land), or
``exec_batch`` (per-object results) — fabric ops AND result frames
scale with the number of OSDs, not the number of objects, on every
path, and wall clock scales with the slowest OSD, not the sum.

Pruning is pushed down by default: the filter expression TREE
(``core.expr`` — OR-groups, IN-lists, ranges, prefixes, negations)
rides serialized inside the batched objclass request and each OSD
skips objects its own CURRENT zone-map xattrs provably rule out (one
interval-arithmetic rule, shared with the client planner) — zero
client zone-map requests and no plan→execute TOCTOU window.  Row
ranges ship the same way: a ``row_slice`` op carries GLOBAL rows that
each OSD resolves against its objects' own extent xattrs.  The classic
client-side prune (``plan``) remains for the ``prune="client"``
strategy: it consults an
epoch-keyed zone-map cache (invalidated wholesale on cluster-epoch
bumps, per object on local rewrites, warmed in one metadata request
per OSD) and revalidates every prune-positive object against the
store's monotonic per-object ``version`` tag — narrowing cross-client
staleness to the plan→execute gap, which only the pushed-down prune
closes entirely.

``LocalVOL`` is the storage-side plugin: it decides the *physical*
representation of each object (layout row/col, per-column codec) from
local information, executes objclass pipelines, and adapts layout to the
observed workload ("physical design management", paper §5) — all without
the client or the access library knowing (independent evolution, goal 3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping

import numpy as np

from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core.logical import (
    Dataspace, Hyperslab, LogicalDataset, RowRange, validate_table)
from repro.core.partition import (
    ArrayObjectMap, ObjectMap, PartitionPolicy, load_objmap, objmap_key,
    plan_array_partition, plan_partition)
from repro.core.scan import Scan, ScanEngine
from repro.core.store import ObjectStore


# --------------------------------------------------------------------------
# LocalVOL — storage-side physical design
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LocalVOL:
    """Per-deployment physical design policy.

    ``codec_for`` picks a per-column codec from the column's value range —
    e.g. token ids bitpack to ceil(log2(vocab)) bits (2-3x over int32).
    ``access_stats`` counts column-scan vs row-fetch requests; when scans
    dominate, stored row-layout objects are transformed to columnar
    (online physical design transformation).
    """

    default_layout: str = "col"
    bitpack_ints: bool = True
    scan_to_row_threshold: float = 0.75
    access_stats: dict[str, int] = dataclasses.field(
        default_factory=lambda: {"scan": 0, "fetch": 0})

    def codecs_for(self, table: Mapping[str, np.ndarray]) -> dict[str, str]:
        return fmt.auto_codecs(table, bitpack_ints=self.bitpack_ints)

    def encode(self, table: Mapping[str, np.ndarray]) -> bytes:
        layout = self.default_layout
        codecs = self.codecs_for(table) if layout == "col" else {}
        return fmt.encode_block(table, layout=layout, codecs=codecs)

    def note_access(self, kind: str) -> None:
        self.access_stats[kind] = self.access_stats.get(kind, 0) + 1

    def preferred_layout(self) -> str:
        s, f = self.access_stats["scan"], self.access_stats["fetch"]
        if s + f == 0:
            return self.default_layout
        return "col" if s / (s + f) >= (1 - self.scan_to_row_threshold) \
            else "row"


# --------------------------------------------------------------------------
# GlobalVOL — client-side decompose / scatter / gather
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReadPlan:
    """The decomposition of one logical request into object sub-requests."""

    sub_requests: tuple            # ((obj_name, local RowRange), ...)
    pruned: tuple                  # object names skipped via zone maps
    pushdown: bool                 # ops run storage-side?


class GlobalVOL:
    def __init__(self, store: ObjectStore, *,
                 local: LocalVOL | None = None, workers: int = 8):
        self.store = store
        self.local = local or LocalVOL()
        self.workers = workers
        # the ONE plan→compile→execute surface (core.scan); read/query/
        # scan and the Skyhook driver all route through it
        self.engine = ScanEngine(self)
        # client-side zone-map cache, keyed by cluster-map epoch:
        # name -> (zone_map, version-it-was-read-at).  Warmed in one
        # batched metadata request per OSD instead of one xattr lookup
        # per object; the version lets ``plan`` detect rewrites by
        # OTHER clients (cross-client coherence).
        self._zm_cache: dict[str, tuple[dict, int]] = {}
        self._zm_epoch: int = -1

    def _pin_epoch(self) -> None:
        """Invalidate the zone-map cache if the cluster map moved; pin
        it to the current epoch (the single invalidation rule, shared by
        the read side and by cache-on-write)."""
        epoch = self.store.cluster.epoch
        if epoch != self._zm_epoch:  # failure/resize: invalidate all
            self._zm_cache.clear()
            self._zm_epoch = epoch

    @staticmethod
    def _zm_entry(xattr: dict) -> tuple[dict, int]:
        return xattr.get("zone_map", {}), int(xattr.get("version", -1))

    def _warm_zone_maps(self, names: Iterable[str]) -> set[str]:
        """Fill cache misses with ONE batched metadata request per OSD
        (K requests for N objects, however cold the cache).  Returns
        the names fetched by THIS call — they are current as of now, so
        the caller can skip revalidating them."""
        self._pin_epoch()
        missing = [n for n in names if n not in self._zm_cache]
        if not missing:
            return set()
        infos = self.store.list_zone_maps(missing)
        for n in missing:
            self._zm_cache[n] = self._zm_entry(infos.get(n, {}))
        return set(missing)

    def _zone_map(self, name: str) -> dict:
        self._pin_epoch()
        ent = self._zm_cache.get(name)
        if ent is None:
            ent = self._zm_entry(self.store.xattr(name))
            self._zm_cache[name] = ent
        return ent[0]

    # ------------------------------------------------------------ create
    def create(self, ds: LogicalDataset,
               policy: PartitionPolicy = PartitionPolicy()) -> ObjectMap:
        """Plan the dataset->object mapping and persist it to the store."""
        omap = plan_partition(ds, policy)
        v = self.store.put(objmap_key(ds.name), omap.to_bytes())
        return dataclasses.replace(omap, version=v)

    def open(self, dataset_name: str) -> ObjectMap | ArrayObjectMap:
        """Bootstrap a dataset's object map from the store alone —
        table (``ObjectMap``) or N-d array (``ArrayObjectMap``), the
        serialized ``kind`` field picks.  The map carries the
        ``.objmap`` object's store version so compiled plans can later
        detect a re-partition (row-slice / hyperslab targeting refresh)
        without re-reading the map."""
        blob, v = self.store.get_with_version(objmap_key(dataset_name))
        return dataclasses.replace(load_objmap(blob), version=v)

    def reopen(self, omap: ObjectMap | ArrayObjectMap
               ) -> ObjectMap | ArrayObjectMap:
        """Cheap staleness check for a held map: probe the ``.objmap``
        object's CURRENT store version (one xattr round trip) and
        re-open only when it moved — e.g. after the maintenance plane's
        compactor rewrote the extents under a long-lived client.  A
        matching version returns the map unchanged."""
        name = omap.dataset.name if isinstance(omap, ObjectMap) \
            else omap.space.name
        v = int(self.store.xattr(objmap_key(name)).get("version", -1))
        if v == omap.version:
            return omap
        return self.open(name)

    # ------------------------------------------------------------ write
    def write(self, omap: ObjectMap, table: Mapping[str, np.ndarray],
              *, rows: RowRange | None = None, workers: int | None = None,
              forwarding: bool = True,
              window_bytes: int | None = None,
              window_objects: int | None = None) -> int:
        """Scatter a row range to its objects through the batched write
        plane: ONE request per primary OSD (with server-side chain
        replication and in-batch failover), so ingest pays K round
        trips for N objects.  Parallelism across OSD groups is the
        store's, gated on ``io_simulated()``; ``workers`` is kept for
        API compatibility and ignored.

        When transfers take simulated time the sub-writes STREAM: the
        per-object encode (slice + zone map + codec) runs lazily and
        ``put_batch``'s windowed mode flushes per-OSD sub-write groups
        as each window of encoded bytes is ready, overlapping encode
        with the NIC stream instead of buffering the whole batch
        (``Fabric.overlap_s`` / ``stream_windows`` measure it).  Pass
        ``window_bytes``/``window_objects`` to pick the window, or
        ``window_objects=0`` to force the buffered path; the default
        defers to ``ObjectStore.default_window_bytes()`` (buffered when
        no I/O is simulated — feeder threads only cost GIL there).
        Stored bytes, versions, and fabric-op counts are identical
        either way.

        ``forwarding=False`` bypasses the plugin machinery and writes one
        native blob — the paper's Table-1 native-HDF5 baseline.
        Returns bytes written (client->store).
        """
        del workers
        ds = omap.dataset
        rows = rows or RowRange(0, ds.n_rows)
        validate_table(ds, table, rows)
        if not forwarding:
            # native access-library path: the app serializes once and
            # writes its LOCAL store — no forwarding hop, no replication
            blob = self.local.encode(dict(table))
            name = f"{ds.name}/native"
            self.store.osds[self.store.cluster.primary(name)].put(name,
                                                                  blob)
            return len(blob)

        subs = omap.lookup(rows)
        # pin the cache to the current epoch so the zone maps we are
        # about to cache-on-write survive the first read-side lookup
        self._pin_epoch()

        if window_bytes is None and window_objects is None:
            window_bytes = self.store.default_window_bytes()
        names = [extent.name for extent, _ in subs]
        zms: list[dict] = []
        nbytes = [0]

        def encoded():
            """Lazy per-object encoder: yields (blob, xattr) pairs for
            ``put_batch`` to stream while the next part encodes."""
            for extent, local_rows in subs:
                glob = local_rows.shift(extent.row_start)
                part = {k: np.asarray(v)[glob.start - rows.start:
                                         glob.stop - rows.start]
                        for k, v in table.items()}
                zm = fmt.zone_map(part)
                zms.append(zm)
                blob = self.local.encode(part)
                nbytes[0] += len(blob)
                yield blob, {"zone_map": zm,
                             "rows": [glob.start, glob.stop]}

        if window_bytes or window_objects:
            versions = self.store.put_batch(
                names, encoded(), window_bytes=window_bytes,
                window_objects=window_objects)
        else:
            items = list(encoded())
            versions = self.store.put_batch(
                names, [b for b, _ in items], [x for _, x in items])
        for name, zm, v in zip(names, zms, versions):
            self._zm_cache[name] = (zm, v)  # keep the cache fresh
        return nbytes[0]

    # ------------------------------------------------------------ arrays
    def create_array(self, space: Dataspace,
                     policy: PartitionPolicy = PartitionPolicy()
                     ) -> ArrayObjectMap:
        """Plan the chunk->object mapping for an N-d dataspace and
        persist it to the store (the array twin of ``create``)."""
        amap = plan_array_partition(space, policy)
        v = self.store.put(objmap_key(space.name), amap.to_bytes())
        return dataclasses.replace(amap, version=v)

    def open_array(self, dataset_name: str) -> ArrayObjectMap:
        """``open`` for arrays; raises if the name maps a table."""
        amap = self.open(dataset_name)
        if not isinstance(amap, ArrayObjectMap):
            raise TypeError(f"{dataset_name!r} is a table dataset; "
                            "use open()")
        return amap

    def write_array(self, amap: ArrayObjectMap, arr: np.ndarray,
                    *, window_bytes: int | None = None,
                    window_objects: int | None = None) -> int:
        """Scatter a full N-d array to its objects through the batched
        write plane.  Each object stores its chunks PADDED to the full
        chunk shape and stacked as one ``(k, *chunk)`` block column, so
        the OSD-side ``hyperslab_local`` executor indexes chunks by
        position; selections never reach the pad because intersections
        are clipped to the logical shape.  Per-chunk zone maps (over
        UNPADDED values) ride in the ``chunk_zone_maps`` xattr — the
        granule OSD-side chunk pruning keys on — next to the
        ``chunks`` extent xattr that late-binds compiled hyperslab
        plans, and an object-level ``zone_map`` merged from them keeps
        whole-object pruning and the client zone-map cache working
        unchanged.  Streams through ``put_batch`` exactly like
        ``write``.  Returns bytes written."""
        sp = amap.space
        arr = np.asarray(arr, dtype=np.dtype(sp.dtype))
        if arr.shape != sp.shape:
            raise ValueError(f"array shape {arr.shape} != dataspace "
                             f"shape {sp.shape}")
        self._pin_epoch()
        if window_bytes is None and window_objects is None:
            window_bytes = self.store.default_window_bytes()
        names = [e.name for e in amap.extents]
        zms: list[dict] = []
        nbytes = [0]

        def encoded():
            for ext in amap.extents:
                stack, czms, unpadded = [], [], []
                for cid in range(ext.chunk_start, ext.chunk_stop):
                    slab = sp.chunk_slab(cid)
                    piece = arr[tuple(slice(a, b) for a, b in slab)]
                    pad = np.zeros(sp.chunk, dtype=arr.dtype)
                    pad[tuple(slice(0, s) for s in piece.shape)] = piece
                    stack.append(pad)
                    czms.append(fmt.zone_map({"data": piece.ravel()}))
                    unpadded.append(piece.ravel())
                zm = fmt.zone_map({"data": np.concatenate(unpadded)})
                zms.append(zm)
                blob = self.local.encode({"data": np.stack(stack)})
                nbytes[0] += len(blob)
                yield blob, {"zone_map": zm,
                             "chunks": [ext.chunk_start, ext.chunk_stop],
                             "chunk_zone_maps": czms}

        if window_bytes or window_objects:
            versions = self.store.put_batch(
                names, encoded(), window_bytes=window_bytes,
                window_objects=window_objects)
        else:
            items = list(encoded())
            versions = self.store.put_batch(
                names, [b for b, _ in items], [x for _, x in items])
        for name, zm, v in zip(names, zms, versions):
            self._zm_cache[name] = (zm, v)
        return nbytes[0]

    def read_array(self, amap: ArrayObjectMap, key,
                   *, where=None, fill=0,
                   prune: str = "auto") -> np.ndarray:
        """Gather one hyperslab selection (a numpy-style index key or a
        :class:`Hyperslab`) through the scan engine — the ``row_slice``
        contract lifted to N dimensions: the GLOBAL selection rides to
        each OSD, which resolves it against its own ``chunks`` xattr
        and prunes whole chunks via ``where`` + per-chunk zone maps."""
        hs = key if isinstance(key, Hyperslab) \
            else Hyperslab.from_key(amap.space.shape, key)
        plan = self.engine.compile_hyperslab(
            amap, hs, where=where, fill=fill, prune=prune)
        out, _ = self.engine.execute(plan, omap=amap)
        return out

    def array(self, dataset: str | ArrayObjectMap) -> "ArrayView":
        """Open an indexable view: ``vol.array("a")[2:10, ::3]``."""
        amap = self.open_array(dataset) if isinstance(dataset, str) \
            else dataset
        return ArrayView(self, amap)

    def repartition_array(self, amap: ArrayObjectMap,
                          policy: PartitionPolicy) -> ArrayObjectMap:
        """Re-pack the array's chunks into objects under a new policy
        and bump the ``.objmap`` version — compiled hyperslab plans
        keep serving correct cells through the late-binding ``chunks``
        xattr and recompile on the version bump (``_refresh``)."""
        sp = amap.space
        full = tuple(slice(0, s) for s in sp.shape)
        data = self.read_array(amap, full, prune="none")
        new = plan_array_partition(sp, policy)
        self.write_array(new, data)
        for name in set(amap.object_names()) - set(new.object_names()):
            self.store.delete(name)
        v = self.store.put(objmap_key(sp.name), new.to_bytes())
        return dataclasses.replace(new, version=v)

    # ------------------------------------------------------------ scan
    def scan(self, dataset: str | ObjectMap) -> Scan:
        """Open a fluent scan over a mapped dataset: compose filters /
        projection / aggregates, then ``.execute()`` (or ``.explain()``
        for the compiled :class:`~repro.core.scan.PhysicalPlan`)."""
        name = dataset if isinstance(dataset, str) \
            else dataset.dataset.name
        return Scan(dataset=name).bind(self)

    # ------------------------------------------------------------ read
    def read(self, omap: ObjectMap, rows: RowRange,
             columns: list[str] | None = None) -> dict[str, np.ndarray]:
        """Gather a row range; per-object select+project run storage-side
        so only requested rows/columns move, and each OSD concatenates
        its result tables into ONE framed response (``exec_concat``)."""
        plan = self.engine.compile_read(omap, rows, columns)
        table, _ = self.engine.execute(plan, omap=omap)
        return table

    # ------------------------------------------------------------ query
    def plan(self, omap: ObjectMap, ops: list[oc.ObjOp],
             names: list[str] | None = None) -> ReadPlan:
        """CLIENT-SIDE prune planning (the ``prune="client"`` strategy;
        the default pushed-down prune needs no client plan at all —
        see ``core.scan``): prune objects whose cached zone maps cannot
        match the filter expression tree (the SAME
        ``objclass.zone_map_prunes`` interval rule the OSDs apply, so
        the two strategies agree bit-exactly on identical metadata).
        ``names`` restricts planning to
        a candidate subset (e.g. a row-ranged scan's objects) so the
        warm/revalidation never touches the rest of the dataset.

        Prune decisions are only as good as the cached zone map, so
        every prune-positive object is revalidated against its current
        xattr ``version`` (one batched metadata request per OSD).  A
        version mismatch means another client rewrote the object at
        this epoch — the fresh zone map replaces the cached one and the
        decision is re-made.  This bounds cross-client staleness to the
        plan→execute gap (a rewrite landing after revalidation is
        caught by the next plan).  Kept objects need no revalidation:
        scanning an object whose zone map went stale is safe, its data
        is read fresh from the OSD."""
        pushdown = oc.pipeline_decomposable(ops)
        names = list(names) if names is not None \
            else [e.name for e in omap]
        prunable = [o for o in ops if o.name == "filter"]
        if not prunable:
            return ReadPlan(tuple((n, None) for n in names), (),
                            pushdown)
        fresh = self._warm_zone_maps(names)  # K requests however cold
        preds = oc.filter_predicates(prunable)

        def prunes(name: str) -> bool:
            return oc.zone_map_prunes(self._zm_cache[name][0], preds)

        keep, pruned = [], []
        for name in names:
            (pruned if prunes(name) else keep).append(name)
        if pruned:  # revalidate prune-positive objects (coherence);
            # entries the warm above just fetched are already current —
            # re-fetching them would double the cold-cache metadata cost
            to_check = [n for n in pruned if n not in fresh]
            if to_check:
                current = self.store.list_zone_maps(to_check)
                for name in to_check:
                    ent = self._zm_entry(current.get(name, {}))
                    if ent[1] != self._zm_cache[name][1]:
                        self._zm_cache[name] = ent  # stale: re-decide
            still = {name for name in pruned if prunes(name)}
            # rebuild in omap (row) order: a revalidated un-prune must
            # not reorder the gather for table-out pipelines
            keep = [n for n in names if n not in still]
            pruned = [n for n in names if n in still]
        return ReadPlan(tuple((k, None) for k in keep), tuple(pruned),
                        pushdown)

    def query(self, omap: ObjectMap, ops: list[oc.ObjOp],
              *, allow_approx: bool = False,
              prune: str = "auto") -> tuple[Any, dict]:
        """Execute an op pipeline over the whole dataset through the
        scan engine: mergeable aggregate tails combine per OSD, table
        tails concatenate per OSD, holistic tails (median) gather their
        projected input — unless ``allow_approx`` rewrites them to the
        decomposable sketch (paper §3.2).  ``prune`` picks the strategy
        ("auto"/"pushdown": predicates ride to the OSDs; "client": the
        cached-zone-map planner; "none").  Returns (result, stats).
        """
        before = self.store.fabric.snapshot()
        plan = self.engine.compile_ops(
            omap, ops, allow_approx=allow_approx, prune=prune)
        return self.engine.execute(plan, before=before, omap=omap)

    # ------------------------------------------------------------ helpers
    def _column_bounds(self, omap: ObjectMap,
                       col: str) -> tuple[float, float]:
        self._warm_zone_maps([e.name for e in omap])
        lo, hi = np.inf, -np.inf
        for extent in omap:
            zm = self._zone_map(extent.name)
            if col in zm:
                lo, hi = min(lo, zm[col][0]), max(hi, zm[col][1])
        if not np.isfinite(lo):
            lo, hi = 0.0, 1.0
        return float(lo), float(hi) + 1e-9


# --------------------------------------------------------------------------
# ArrayView — numpy-style front end over a mapped dataspace
# --------------------------------------------------------------------------


class ArrayView:
    """Indexable handle over one mapped N-d dataspace: ``view[key]``
    compiles the key to a hyperslab plan and executes it (storage-side
    selection + chunk pruning), returning a dense ndarray shaped like
    ``np.asarray(full)[key]`` would be.  ``sel`` adds the pushed-down
    ``where`` predicate (cells whose chunk is pruned come back as
    ``fill``) — the array analogue of ``Scan.filter``."""

    def __init__(self, vol: GlobalVOL, amap: ArrayObjectMap):
        self.vol = vol
        self.amap = amap

    @property
    def space(self) -> Dataspace:
        return self.amap.space

    @property
    def shape(self) -> tuple[int, ...]:
        return self.amap.space.shape

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.amap.space.dtype)

    def __getitem__(self, key) -> np.ndarray:
        return self.vol.read_array(self.amap, key)

    def sel(self, key, *, where=None, fill=0,
            prune: str = "auto") -> np.ndarray:
        return self.vol.read_array(self.amap, key, where=where,
                                   fill=fill, prune=prune)

    def refresh(self) -> "ArrayView":
        """Re-open the map (picks up a re-partition)."""
        self.amap = self.vol.open_array(self.amap.space.name)
        return self
