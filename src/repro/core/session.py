"""ScanSession — the client half of the hot-data serve plane.

The OSD-side :class:`~repro.core.cache.ResultCache` makes a repeated
scan cheap; this layer makes it cheap *before* it ever reaches an OSD.
A :class:`ScanSession` fronts one :class:`~repro.core.vol.GlobalVOL`
for a many-client workload and applies two dedup layers to the
concurrent scans admitted through it:

**Single-flight.**  Identical scans that overlap in time collapse into
ONE execution: the first arrival (the leader) runs the scan, every
later identical arrival (a joiner) parks on the flight and receives
the same result — N identical concurrent scans cost one OSD round
trip, fanned out N ways.  Identity is the scan's compiled pipeline
digest (``objclass.pipeline_digest`` over the serialized ops), so two
fluent chains that describe the same pipeline dedup even when built
independently.

**Column coalescing.**  Table-out scans that differ ONLY in their
projection share a flight too: during the admission window the
flight's column set grows to the union, the leader executes once with
the widened projection, and each waiter gets exactly its requested
columns sliced out — same-object different-column requests become one
request.  A scan arriving after the flight sealed still joins when its
columns are a subset of what is already in flight.

Results fan out by reference (column arrays are never copied), which
is safe for the same reason the OSD cache is: every layer of the scan
plane builds new dicts rather than mutating served tables.  Errors fan
out too — a failed flight raises the leader's exception in every
waiter.  The session itself adds no coherence hazard: dedup only ever
merges scans into one REAL execution against the store, so every
result a waiter sees was served (and version-checked) by the OSDs at
one point in time; there is no client-side result reuse across calls.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

from repro.core import objclass as oc


class _Flight:
    """One in-flight scan execution and the waiters parked on it."""

    __slots__ = ("cols", "sealed", "done", "result", "stats", "error",
                 "waiters")

    def __init__(self, cols: tuple[str, ...] | None):
        # the union of every joined waiter's projection; None for
        # non-coalescible flights (exact-pipeline dedup only)
        self.cols: set[str] | None = set(cols) if cols is not None \
            else None
        self.sealed = False      # column set frozen (leader is executing)
        self.done = threading.Event()
        self.result: Any = None  # full-union result (leader's output)
        self.stats: dict | None = None
        self.error: BaseException | None = None
        self.waiters = 1


class ScanSession:
    """Admission front-end for many concurrent clients scanning one vol.

    ``window_s`` is the admission window: a flight's leader holds the
    execution open that long so concurrent arrivals can join (and
    coalescible ones widen the projection) before the single OSD round
    trip goes out.  ``0`` disables the hold — single-flight dedup then
    only catches arrivals that overlap an execution already in flight.

    Thread-safe; meant to be shared across client threads.  ``stats``
    counts admissions/executions/dedups under the session lock::

        session = ScanSession(vol, window_s=0.002)
        result, stats = session.execute(vol.scan("ds").project("x"))
    """

    # lock-discipline contract (see ``repro.analysis``): the flight
    # table and the admission counters are mutated by every client
    # thread entering the session
    _GUARDED_BY = {"_flights": "_lock", "stats": "_lock"}

    def __init__(self, vol, *, window_s: float = 0.0):
        self.vol = vol
        self.window_s = float(window_s)
        self._lock = threading.Lock()
        self._flights: dict[tuple, _Flight] = {}
        self.stats = {
            "admitted": 0,    # scans entering the session
            "executed": 0,    # real OSD executions issued
            "deduped": 0,     # scans served by joining a flight
            "coalesced": 0,   # joins that widened a flight's columns
            "solo": 0,        # sealed-flight misses run standalone
        }

    # ------------------------------------------------------------ keys
    @staticmethod
    def _identity(scan) -> tuple[tuple, tuple[str, ...] | None]:
        """``(flight_key, cols)``: the dedup key and, for coalescible
        scans, the projection kept OUT of the key so flights can merge
        columns.  Non-coalescible scans (aggregates, median, full-table
        reads) dedup on the exact pipeline instead (``cols`` None)."""
        coalescible = (scan.projection is not None
                       and not scan.aggregates
                       and scan.median_col is None)
        if coalescible:
            base = dataclasses.replace(scan, projection=None)
            return ((scan.dataset, scan.approx, scan.prune_strategy,
                     oc.pipeline_digest(base.pipeline()), "cols"),
                    tuple(scan.projection))
        return ((scan.dataset, scan.approx, scan.prune_strategy,
                 oc.pipeline_digest(scan.pipeline()), "exact"), None)

    # ------------------------------------------------------------ serve
    def execute(self, scan) -> tuple[Any, dict]:
        """Run one scan through the session: join an open (or still
        compatible) flight when one exists, otherwise lead a new one.
        Returns ``(result, stats)`` exactly like ``Scan.execute``."""
        scan = scan.bind(self.vol, scan._runner)
        key, cols = self._identity(scan)
        with self._lock:
            self.stats["admitted"] += 1
            flight = self._flights.get(key)
            if flight is None:
                flight = _Flight(cols)
                self._flights[key] = flight
                self.stats["executed"] += 1
                role = "lead"
            elif cols is None or not flight.sealed:
                # open flight: a coalescible joiner widens the union
                if cols is not None and not set(cols) <= flight.cols:
                    flight.cols |= set(cols)
                    self.stats["coalesced"] += 1
                flight.waiters += 1
                self.stats["deduped"] += 1
                role = "join"
            elif flight.cols is not None and set(cols) <= flight.cols:
                # sealed but already fetching a superset: pure dedup
                flight.waiters += 1
                self.stats["deduped"] += 1
                role = "join"
            else:
                # sealed flight fetching too little: run standalone
                # (re-keying the dict entry would strand its joiners)
                self.stats["solo"] += 1
                self.stats["executed"] += 1
                role = "solo"
        if role == "join":
            flight.done.wait()
            if flight.error is not None:
                raise flight.error
            return self._slice(flight.result, cols), dict(flight.stats)
        if role == "solo":
            return scan.execute()
        return self._lead(key, flight, scan, cols)

    def _lead(self, key: tuple, flight: _Flight, scan,
              cols) -> tuple[Any, dict]:
        if self.window_s > 0:
            time.sleep(self.window_s)  # admission window: concurrent
            #                            arrivals join before we seal
        with self._lock:
            flight.sealed = True
            union = tuple(sorted(flight.cols)) \
                if flight.cols is not None else None
        run = scan
        if union is not None and set(union) != set(cols):
            run = dataclasses.replace(scan, projection=union)
        try:
            flight.result, flight.stats = run.execute()
        except BaseException as e:
            flight.error = e
            raise
        finally:
            with self._lock:
                # pop BEFORE waking waiters: a scan arriving now must
                # lead a fresh execution, not adopt a finished one
                self._flights.pop(key, None)
            flight.done.set()
        return self._slice(flight.result, cols), dict(flight.stats)

    @staticmethod
    def _slice(result, cols) -> Any:
        if cols is None or not isinstance(result, dict):
            return result
        return {c: result[c] for c in cols}
