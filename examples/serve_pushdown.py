"""Serving example: batched generation + KV-cache pages as objects +
storage-side analytics over the request log.

  PYTHONPATH=src python examples/serve_pushdown.py

Shows the serving-side of the paper's idea: session state (the decode
KV cache) is parked to / revived from the same object store that holds
the training data, and the request log is a mapped dataset whose
aggregations run storage-side.
"""

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        make_store)
from repro.core import objclass as oc
from repro.models.archs import build_model
from repro.serve.engine import Request, ServeEngine

store = make_store(6, replicas=2)
vol = GlobalVOL(store)

# -- a small model serving batched requests -------------------------------
cfg = get_config("yi_9b", smoke=True)
model = build_model(cfg, remat="none")
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, max_seq=128, store=store)

rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(1, cfg.vocab_size,
                                    rng.integers(4, 24)).astype(np.int32),
                max_new=12) for _ in range(8)]
t0 = time.perf_counter()
comps = engine.generate(reqs)
dt = time.perf_counter() - t0
total_new = sum(c.steps for c in comps)
print(f"served {len(reqs)} requests, {total_new} tokens in "
      f"{dt * 1e3:.0f} ms ({total_new / dt:.1f} tok/s on 1 CPU core)")

# -- park the batch's KV cache as objects, revive it -----------------------
engine.park_session("batch-0")
kv_objects = [n for n in store.list_objects("kv/")]
cache = engine.resume_session("batch-0", batch=len(reqs))
ok = all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(
    jax.tree.leaves(jax.device_get(engine._last_cache)),
    jax.tree.leaves(jax.device_get(cache))))
print(f"KV cache parked as {len(kv_objects)} objects and revived "
      f"bit-exact: {ok}")

# -- request log as a mapped dataset, analytics pushed down ----------------
n = 50_000
log = LogicalDataset(
    "reqlog",
    (Column("latency_ms", "float32"), Column("tokens_out", "int32"),
     Column("model_id", "int32")),
    n_rows=n, unit_rows=1024)
omap = vol.create(log, PartitionPolicy(target_object_bytes=256 << 10))
vol.write(omap, {
    "latency_ms": rng.gamma(3, 12, n).astype(np.float32),
    "tokens_out": rng.integers(1, 512, n).astype(np.int32),
    "model_id": rng.integers(0, 4, n).astype(np.int32),
})
p50, st = vol.query(omap, [oc.op("median", col="latency_ms")],
                    allow_approx=True)
slow, _ = vol.query(omap, [
    oc.op("filter", col="latency_ms", cmp=">", value=100.0),
    oc.op("agg", col="tokens_out", fn="count")])
print(f"request-log analytics storage-side: p50 latency ~{p50:.1f} ms, "
      f"{int(slow)} slow requests; {st['client_rx']} B moved to client")
