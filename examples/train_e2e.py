"""End-to-end training driver: a ~100M-param LM trained for a few hundred
steps with the entire data/checkpoint path on the object store.

  PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 300
  PYTHONPATH=src python examples/train_e2e.py --preset 25m  --steps 200
  PYTHONPATH=src python examples/train_e2e.py --preset tiny --steps 40

Everything the paper promises is on: dataset mapped to objects with
planar-bitpacked token columns; loader fetches packed rows with the
zero-decode ``select_packed`` objclass op and hedges stragglers;
the unpack happens inside the compiled step; checkpoints are replicated
objects committed manifest-last; an OSD is killed mid-run and the run
continues; the final restart proves bit-determinism.

Results land in results/train_e2e_<preset>.json.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import GlobalVOL, make_store
from repro.core.partition import PartitionPolicy
from repro.data.corpus import CorpusSpec, build_corpus
from repro.data.pipeline import ObjectDataLoader
from repro.models.archs import build_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~103M params: 12L d=768 (gpt2-small-ish, llama-style blocks)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab_size=32_000,
                 batch=8, seq=256),
    # ~27M params
    "25m": dict(n_layers=8, d_model=448, n_heads=8, n_kv_heads=4,
                head_dim=56, d_ff=1280, vocab_size=16_000,
                batch=8, seq=256),
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 head_dim=32, d_ff=384, vocab_size=2_000,
                 batch=8, seq=128),
}


def make_cfg(p: dict) -> ArchConfig:
    import jax.numpy as jnp
    return ArchConfig(
        name="train_e2e", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"],
        param_dtype=jnp.float32, compute_dtype=jnp.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--kill-osd-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = make_cfg(p)
    print(f"[e2e] {args.preset}: {cfg.param_count() / 1e6:.1f}M params")

    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    n_seqs = max(args.steps * p["batch"] // 4, 512)  # ~4 epochs
    build_corpus(vol, CorpusSpec(n_seqs=n_seqs, seq_len=p["seq"],
                                 vocab_size=cfg.vocab_size,
                                 seed=args.seed),
                 policy=PartitionPolicy(target_object_bytes=2 << 20,
                                        max_object_bytes=16 << 20))
    print(f"[e2e] corpus: {n_seqs} x {p['seq']} tokens in "
          f"{store.stats()['n_objects']} objects")

    model = build_model(cfg, remat="none")
    loader = ObjectDataLoader(vol, "corpus", global_batch=p["batch"],
                              seed=args.seed, packed=True, prefetch=2,
                              hedge_timeout_s=0.5)
    kill_at = args.kill_osd_at or args.steps // 2

    path = pathlib.Path(__file__).resolve().parents[1] / "results"
    path.mkdir(exist_ok=True)
    out_file = path / f"train_e2e_{args.preset}.json"

    def write_partial(history) -> None:
        losses = [h["loss"] for h in history]
        out_file.write_text(json.dumps({
            "preset": args.preset, "params_m": cfg.param_count() / 1e6,
            "steps_done": len(losses), "steps_target": args.steps,
            "loss_first": losses[0], "loss_last": losses[-1],
            "loss_curve": losses[:: max(len(losses) // 50, 1)],
            "wall_s_per_step": float(np.mean(
                [h["wall_s"] for h in history[2:]] or [0.0])),
        }, indent=1))

    def on_step(step: int) -> None:
        if step == kill_at:
            victim = store.cluster.up_osds[0]
            store.fail_osd(victim)
            rec = store.recover()
            print(f"[e2e] step {step}: killed {victim}; recovery moved "
                  f"{rec['objects_moved']} replicas, lost "
                  f"{rec['objects_lost']}")
        if step % 10 == 0:
            write_partial(trainer.history)

    trainer = Trainer(
        model, loader, store,
        opt=OptConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps),
        cfg=TrainerConfig(total_steps=args.steps,
                          ckpt_every=max(args.steps // 4, 10),
                          log_every=max(args.steps // 20, 5),
                          packed_ingest=True))
    state = trainer.run(on_step=on_step)
    loader.close()

    losses = [h["loss"] for h in trainer.history]
    out = {
        "preset": args.preset,
        "params_m": cfg.param_count() / 1e6,
        "steps": args.steps,
        "loss_first": losses[0], "loss_last": losses[-1],
        "loss_curve": losses[:: max(len(losses) // 50, 1)],
        "stragglers_flagged": trainer.straggler.flagged,
        "store": store.stats()["fabric"],
        "wall_s_per_step": float(np.mean(
            [h["wall_s"] for h in trainer.history[2:]])),
    }
    out_file.write_text(json.dumps(out, indent=1))
    print(f"[e2e] loss {out['loss_first']:.3f} -> {out['loss_last']:.3f} "
          f"over {args.steps} steps "
          f"({out['wall_s_per_step'] * 1e3:.0f} ms/step); "
          f"results -> results/train_e2e_{args.preset}.json")
    assert out["loss_last"] < out["loss_first"], "training must learn"


if __name__ == "__main__":
    main()
