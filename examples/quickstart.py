"""Quickstart: the paper's system in ~60 lines.

1. stand up a replicated object store (Ceph stand-in)
2. map a logical dataset onto objects through the GlobalVOL
3. run storage-side scans through the composable builder
   (filters AND together, aggregates compose, pruning happens ON the
   OSDs, table results come back as one framed response per OSD)
4. survive an OSD failure
5. train a tiny LM whose data path IS that object store

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        RowRange, SkyhookDriver, make_store)

# -- 1. an 8-OSD cluster, 3-way replication ------------------------------
store = make_store(8, replicas=3)
vol = GlobalVOL(store)

# -- 2. map a dataset to objects ------------------------------------------
ds = LogicalDataset(
    "sensors",
    (Column("temp", "float32"), Column("station", "int32")),
    n_rows=100_000, unit_rows=512)
omap = vol.create(ds, PartitionPolicy(target_object_bytes=64 << 10))
rng = np.random.default_rng(0)
vol.write(omap, {
    "temp": rng.normal(15, 8, ds.n_rows).astype(np.float32),
    "station": rng.integers(0, 50, ds.n_rows).astype(np.int32),
})
print(f"mapped {ds.n_rows} rows -> {omap.n_objects} objects on "
      f"{len(store.cluster.osds)} OSDs")

# -- 3. composable pushdown scans -----------------------------------------
stats_hot, stats = (vol.scan("sensors")
                    .filter("station", "==", 7)
                    .agg("mean", "temp").agg("count", "temp")
                    .execute())
print(f"mean(temp | station==7) = {stats_hot['mean(temp)']:.3f} over "
      f"{stats_hot['count(temp)']:.0f} rows  "
      f"[{stats['client_rx']} B moved, {stats['local_bytes']} B scanned "
      f"storage-side, {stats['exec_class']}, zero zone-map round trips "
      f"({stats['xattr_ops']})]")

cold, stats = (vol.scan("sensors").filter("temp", "<", -20)
               .project("temp", "station").execute())
print(f"filter→project: {stats['result_rows']} matching rows back in "
      f"{stats['rx_frames']} framed responses "
      f"({stats['objects_pruned']} objects pruned ON their OSDs)")

drv = SkyhookDriver(vol, n_workers=4)
med, qstats = drv.execute(drv.scan("sensors")
                          .median("temp", approx=True))
print(f"median(temp) ~= {med:.3f}  [approx sketch, "
      f"{qstats.client_rx_bytes} B moved, pushdown={qstats.pushdown}]")

# -- 4. kill an OSD mid-flight --------------------------------------------
victim = store.cluster.primary(omap.object_names()[0])
store.fail_osd(victim)
rec = store.recover()
rows = vol.read(omap, RowRange(0, 5))
print(f"killed {victim}: recovered {rec['objects_moved']} replicas, "
      f"lost {rec['objects_lost']}; reads fine: temp[:5]="
      f"{np.round(rows['temp'], 2)}")

# -- 5. train a tiny LM straight off the store -----------------------------
import jax
from repro.configs.base import get_config
from repro.data.corpus import CorpusSpec, build_corpus
from repro.data.pipeline import ObjectDataLoader
from repro.models.archs import build_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

cfg = get_config("yi_9b", smoke=True)
build_corpus(vol, CorpusSpec(n_seqs=256, seq_len=128,
                             vocab_size=cfg.vocab_size))
model = build_model(cfg, remat="none")
loader = ObjectDataLoader(vol, "corpus", global_batch=8, packed=True)
trainer = Trainer(model, loader, store, opt=OptConfig(lr=1e-3),
                  cfg=TrainerConfig(total_steps=20, ckpt_every=10,
                                    log_every=5, packed_ingest=True))
trainer.run()
print(f"trained 20 steps off the object store "
      f"(loss {trainer.history[0]['loss']:.2f} -> "
      f"{trainer.history[-1]['loss']:.2f}); checkpoints are objects too: "
      f"{len(store.list_objects('ckpt/'))} stored")
