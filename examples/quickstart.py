"""Quickstart: the paper's system in ~80 lines.

1. stand up a replicated object store (Ceph stand-in)
2. map a logical dataset onto objects through the GlobalVOL
3. run storage-side scans through the composable builder
   (filters AND together, aggregates compose, pruning happens ON the
   OSDs, table results come back as one framed response per OSD)
4. stream a windowed ingest: encode overlaps the NIC, replicas chain
5. survive failures: fail-stop OSD loss, injected bit rot (digest
   verify + scrub/heal), torn writes, and transient gray failures
   (bounded-backoff retries; loud DataLossError when data is truly gone)
6. train a tiny LM whose data path IS that object store (the loader's
   windowed fetch assembles early batches while slow OSDs still serve)
7. (…and serve it hot: OSD result caches + single-flight sessions)
8. slice an N-d array: numpy-style hyperslab selections resolved ON
   the OSDs (chunked dataspaces, per-chunk zone-map pruning — wire
   bytes track the selection, not the array)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (Column, FaultInjector, GlobalVOL, LogicalDataset,
                        PartitionPolicy, RowRange, SkyhookDriver,
                        make_store)

# -- 1. an 8-OSD cluster, 3-way replication ------------------------------
store = make_store(8, replicas=3)
vol = GlobalVOL(store)

# -- 2. map a dataset to objects ------------------------------------------
ds = LogicalDataset(
    "sensors",
    (Column("temp", "float32"), Column("station", "int32")),
    n_rows=100_000, unit_rows=512)
omap = vol.create(ds, PartitionPolicy(target_object_bytes=64 << 10))
rng = np.random.default_rng(0)
vol.write(omap, {
    "temp": rng.normal(15, 8, ds.n_rows).astype(np.float32),
    "station": rng.integers(0, 50, ds.n_rows).astype(np.int32),
})
print(f"mapped {ds.n_rows} rows -> {omap.n_objects} objects on "
      f"{len(store.cluster.osds)} OSDs")

# -- 3. composable pushdown scans -----------------------------------------
stats_hot, stats = (vol.scan("sensors")
                    .filter("station", "==", 7)
                    .agg("mean", "temp").agg("count", "temp")
                    .execute())
print(f"mean(temp | station==7) = {stats_hot['mean(temp)']:.3f} over "
      f"{stats_hot['count(temp)']:.0f} rows  "
      f"[{stats['client_rx']} B moved, {stats['local_bytes']} B scanned "
      f"storage-side, {stats['exec_class']}, zero zone-map round trips "
      f"({stats['xattr_ops']})]")

cold, stats = (vol.scan("sensors").filter("temp", "<", -20)
               .project("temp", "station").execute())
print(f"filter→project: {stats['result_rows']} matching rows back in "
      f"{stats['rx_frames']} framed responses "
      f"({stats['objects_pruned']} objects pruned ON their OSDs)")

drv = SkyhookDriver(vol, n_workers=4)
med, qstats = drv.execute(drv.scan("sensors")
                          .median("temp", approx=True))
print(f"median(temp) ~= {med:.3f}  [approx sketch, "
      f"{qstats.client_rx_bytes} B moved, pushdown={qstats.pushdown}]")

# -- 3b. expression filters + OSD-side row ranges --------------------------
# filters are a full predicate ALGEBRA (core.expr): OR-groups, IN-lists,
# ranges, negations, string prefixes — the whole tree ships serialized
# inside the batched request, each OSD evaluates it with vectorized
# masks AND prunes with interval arithmetic against its own current
# zone maps (an Or prunes only when EVERY branch provably misses; Not
# never prunes — conservative by construction, so prune="client" and
# prune="pushdown" always agree)
extremes, stats = (vol.scan("sensors")
                   .or_(("temp", "<", -10), ("temp", ">", 40))
                   .isin("station", [7, 11, 13])
                   .project("temp", "station").execute())
print(f"OR/IN scan: {stats['result_rows']} extreme rows from 3 stations "
      f"in {stats['rx_frames']} frames, {stats['objects_pruned']} objects "
      f"pruned ON their OSDs, {stats['xattr_ops']} zone-map round trips")

# .rows() ships as a row_slice op carrying GLOBAL rows: each OSD
# resolves its objects' sub-ranges from their own extent xattrs at
# execute time, so one compiled plan keeps serving the right rows even
# after the dataset is re-partitioned — and a row-ranged aggregate now
# rides the same per-OSD combine plane as a whole-table scan
windowed, stats = (vol.scan("sensors").rows(10_000, 60_000)
                   .filter("temp", ">", 20).agg("mean", "temp")
                   .execute())
print(f"rows[10k:60k] mean(temp|>20) = {windowed:.2f}  "
      f"[{stats['exec_class']}, prune={stats['prune']}]")

# -- 4. streaming pipelined ingest ----------------------------------------
# with a transport model (shared client NIC, per-OSD disks) vol.write
# STREAMS: per-OSD sub-write groups flush as the encoder produces
# blobs, so encode overlaps the NIC instead of running ahead of it, and
# each replica write pipelines entry -> replica -> replica (chain), so
# the entry OSD sends each blob once.  (table1_forwarding measures
# ~1.7x over buffered encode-then-stream at the 192 MB scale.)
sim = make_store(4, replicas=3, client_bw=400 << 20, disk_bw=200 << 20)
svol = GlobalVOL(sim)
sds = LogicalDataset("stream_demo",
                     (Column("tokens", "int32", (64,)),),
                     n_rows=20_000, unit_rows=512)
somap = svol.create(sds, PartitionPolicy(target_object_bytes=1 << 20))
sim.fabric.reset()
svol.write(somap, {"tokens": rng.integers(0, 1 << 15, (20_000, 64))
                   .astype(np.int32)}, window_bytes=256 << 10)
f = sim.fabric
print(f"streamed ingest: {f.ops} put requests (one per OSD) in "
      f"{f.stream_windows} windows, {f.overlap_s * 1e3:.0f}ms encode "
      f"hidden behind the NIC; chain replication: entry OSD egress "
      f"{f.entry_egress_bytes >> 20}MB of {f.replica_bytes >> 20}MB "
      f"total replica traffic")

# -- 5. surviving failures -------------------------------------------------
# 5a. fail-stop: kill an OSD, peering re-replicates from digest-
# verified survivors.  recover() is LOUD about real data loss: it
# raises DataLossError naming the objects (allow_loss=True opts back
# into the stats-only behavior for benchmarks).
victim = store.cluster.primary(omap.object_names()[0])
store.fail_osd(victim)
rec = store.recover()
rows = vol.read(omap, RowRange(0, 5))
print(f"killed {victim}: recovered {rec['objects_moved']} replicas, "
      f"lost {rec['objects_lost']}; reads fine: temp[:5]="
      f"{np.round(rows['temp'], 2)}")

# 5b. gray failures: every write stamped a content digest into the
# object's xattrs (put, batched windows, every replica-chain hop), so
# every copy is independently verifiable.  Inject bit rot on a primary
# copy: the read digest-checks it, quarantines the bad copy on its
# OSD, and fails over to a verified replica — bit-exact, zero wrong
# bytes to the client.
hit = omap.extents[1]
target = hit.name
fi = FaultInjector(store)
fi.flip_bits(target, osd_id=store.cluster.locate(target)[0], n_bits=3)
_ = vol.read(omap, hit.rows)  # served from a verified replica
print(f"bit rot on {target}'s primary: read stayed bit-exact, "
      f"{store.fabric.corruptions_detected} corruption detected + "
      f"quarantined")

# scrub() is the maintenance half: walk every OSD, verify each copy
# against its digest, quarantine divergent/torn copies, heal from the
# highest-version verified source through the replication chain.  A
# second scrub finds nothing (idempotent).
fi.tear_write(omap.object_names()[2])  # blob landed, xattrs lost
sc = store.scrub()
print(f"scrub: {sc['objects_scrubbed']} objects verified "
      f"({store.fabric.scrub_bytes >> 20} MB), {sc['corrupt_copies']} "
      f"corrupt/torn copies found, {sc['healed_copies']} healed through "
      f"the chain; second scrub finds "
      f"{store.scrub()['corrupt_copies']}")

# 5c. retry/deadline knobs: transient faults (an OSD failing N requests
# then recovering) are retried with bounded exponential backoff under a
# per-request deadline — RetryPolicy(attempts, base_s, cap_s,
# deadline_s) on make_store(retry=...).  Exhaustion fails over to the
# next replica; only when EVERY replica is lost or corrupt does the
# client see a DataLossError naming the objects.
fi.transient_failures(store.cluster.up_osds[0], 2)
n_all, _ = vol.scan("sensors").agg("count", "temp").execute()
print(f"transient faults: scan retried ({store.fabric.retries} retries) "
      f"and still counted {n_all:.0f} rows")

# -- 6. serving hot data: OSD caches + single-flight sessions --------------
# a serving cluster sees the SAME scans from thousands of clients.
# cache_bytes gives every OSD a byte-bounded LRU of decoded columns and
# pipeline results keyed by (object, xattr version, pipeline digest) —
# the monotonic version stamped by every write path makes invalidation
# exact, so a rewrite/heal/quarantine can never serve a stale byte.
# scan_bw models the per-OSD decode service queue; cache hits skip it.
import threading

from repro.core import ScanSession

hot = make_store(4, replicas=2, scan_bw=200 << 20, cache_bytes=32 << 20)
hvol = GlobalVOL(hot)
hds = LogicalDataset("hotset", (Column("temp", "float64"),
                                Column("station", "int32")),
                     n_rows=40_000, unit_rows=512)
homap = hvol.create(hds, PartitionPolicy(target_object_bytes=128 << 10))
hvol.write(homap, {"temp": rng.normal(15.0, 8.0, 40_000),
                   "station": rng.integers(0, 500, 40_000)
                   .astype(np.int32)})
q = hvol.scan("hotset").filter("station", "<", 100).project("temp")
q.execute()                     # cold: every OSD decodes from device
b0, w0 = hot.fabric.local_bytes, hot.fabric.queue_wait_s
q.execute()                     # warm: served from the OSD caches
print(f"hot repeat: {hot.fabric.cache_hits} cache hits, "
      f"{hot.fabric.local_bytes - b0} new bytes decoded, "
      f"{(hot.fabric.queue_wait_s - w0) * 1e3:.1f}ms queue wait — "
      f"hits skip the service queue entirely")

# the client half: a ScanSession single-flights identical concurrent
# scans (N clients, ONE OSD round trip, result fanned out N ways) and
# coalesces same-scan different-column requests into one widened fetch
sess = ScanSession(hvol, window_s=0.02)
agg = hvol.scan("hotset").filter("temp", ">", 20.0).agg("count", "temp")
ops0 = hot.fabric.ops
clients = [threading.Thread(target=sess.execute, args=(agg,))
           for _ in range(8)]
for c in clients:
    c.start()
for c in clients:
    c.join()
print(f"single-flight: 8 identical concurrent scans -> "
      f"{sess.stats['executed']} execution "
      f"({hot.fabric.ops - ops0} requests — one scan's worth), "
      f"{sess.stats['deduped']} served by fan-out")

# -- 7. train a tiny LM straight off the store -----------------------------
from repro.configs.base import get_config
from repro.data.corpus import CorpusSpec, build_corpus
from repro.data.pipeline import ObjectDataLoader
from repro.models.archs import build_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

cfg = get_config("yi_9b", smoke=True)
build_corpus(vol, CorpusSpec(n_seqs=256, seq_len=128,
                             vocab_size=cfg.vocab_size))
model = build_model(cfg, remat="none")
# window_steps=2: the loader fetches two steps' rows in one streaming
# gather and assembles each batch the moment ITS frames land
loader = ObjectDataLoader(vol, "corpus", global_batch=8, packed=True,
                          window_steps=2)
trainer = Trainer(model, loader, store, opt=OptConfig(lr=1e-3),
                  cfg=TrainerConfig(total_steps=20, ckpt_every=10,
                                    log_every=5, packed_ingest=True))
trainer.run()
print(f"trained 20 steps off the object store "
      f"(loss {trainer.history[0]['loss']:.2f} -> "
      f"{trainer.history[-1]['loss']:.2f}); checkpoints are objects too: "
      f"{len(store.list_objects('ckpt/'))} stored")

# -- 8. N-d arrays: hyperslab selection pushdown ---------------------------
# scientific datasets are chunked N-d arrays, not tables.  A Dataspace
# maps chunks onto objects; numpy-style selections compile to ONE
# GLOBAL hyperslab op that every OSD resolves against its own 'chunks'
# xattr (late binding — re-partition the array and compiled plans keep
# serving correct cells), and a predicate prunes whole chunks from
# per-chunk zone maps before any cell is decoded.
from repro.core import Cmp, Dataspace

cube = Dataspace(name="cube", shape=(64, 64, 32), dtype="float64",
                 chunk=(16, 16, 8))
field = rng.uniform(0.0, 1.0, cube.shape)
field[:16, :16, :8] += 100.0                      # one hot corner
cmap = vol.create_array(cube, PartitionPolicy(
    target_object_bytes=256 << 10))
vol.write_array(cmap, field)
view = vol.array("cube")

store.fabric.reset()
sub = view[8:56:2, ::4, 5]                        # strided 2-d slice
assert np.array_equal(sub, field[8:56:2, ::4, 5])
print(f"hyperslab [8:56:2, ::4, 5]: {sub.size} cells in "
      f"{store.fabric.rx_frames} framed responses, "
      f"{store.fabric.client_rx} B on the wire "
      f"(the full array is {field.nbytes} B)")

store.fabric.reset()
hot_cells = view.sel(np.s_[:, :, :], where=Cmp("data", ">", 50.0))
print(f"where data>50: {store.fabric.chunks_pruned} cold chunks pruned "
      f"ON the OSDs from per-chunk zone maps "
      f"({store.fabric.xattr_ops} client zone-map round trips)")

# -- 9. keeping the cluster healthy ----------------------------------------
# long-lived clusters stay healthy through the maintenance plane: a
# continuous scrub walker (rate-limited digest verify + heal), a
# small-object compactor (folds one-blob-per-append streams into
# target-sized objects and rewrites the .objmap with a version bump —
# compiled plans re-target on their next execute), a live rebalancer
# (copy-verify-drop toward the current placement after topology
# changes), and versioned GC (reclaims replaced members + quarantined
# copies after an operator-confirmed retention window).  All of it
# runs WHILE the serve plane keeps answering, bit-exactly.
from repro.core import Column, LogicalDataset, MaintenancePlane

stream = LogicalDataset("stream", (Column("v", "float64"),), 4096, 32)
smap = vol.create(stream, PartitionPolicy(target_object_bytes=32 * 8))
svals = rng.normal(size=4096)
vol.write(smap, {"v": svals})            # 1 tiny object per append
n_small = smap.n_objects

plane = MaintenancePlane(
    store, scrub_rate_bytes_s=512e6,     # trickle, don't burst
    compact_policy=PartitionPolicy(target_object_bytes=48 << 10),
    compact_datasets=["stream"], gc_retention_s=0.1)
plane.start()                            # all four daemons
plane.confirm_gc()                       # operator signs off on GC

import time
prev = -1
while plane.compact_runs != prev:        # let compaction settle
    prev = plane.compact_runs
    time.sleep(0.05)
fi.flip_bits(vol.open("stream").object_names()[0])  # rot a live copy
while plane.scrub_corrupt == 0:          # the walker finds + heals it
    time.sleep(0.01)
live = vol.read(vol.open("stream"), RowRange(0, 4096))  # serve plane
assert np.array_equal(live["v"], svals), "maintenance must be invisible"
time.sleep(0.15)                         # retention window passes
plane.gc_step()                          # (or just leave the daemon to it)
plane.stop()
print(f"maintenance plane: compacted {n_small} tiny objects -> "
      f"{vol.open('stream').n_objects}, walker detected+healed "
      f"{plane.scrub_corrupt} rotten copy, GC reclaimed "
      f"{store.fabric.gc_objects} retired objects "
      f"({store.fabric.gc_bytes >> 10} KB) — live reads stayed bit-exact")

# -- 10. verifying the invariants ------------------------------------------
# Everything above leans on contracts no unit test can enforce: Fabric
# counters are caller-thread-owned, _GUARDED_BY state only moves under
# its lock, every write path stamps a digest and invalidates caches,
# every objclass op round-trips the wire.  The verification plane
# checks them structurally — run it like CI does:
#
#   PYTHONPATH=src python -m repro.analysis        # static AST linter
#   PYTHONPATH=src python -m pytest tests/test_serve_plane.py \
#       tests/test_maintenance.py -q --lockcheck   # lock-order harness
#
# The linter must exit 0 with zero unsuppressed findings (intentional
# exceptions live in src/repro/analysis/suppressions.txt, each with a
# justification); --lockcheck fails the suite on any lock-order cycle
# or unlocked guarded mutation, even if nothing deadlocked.  Here we
# just run the registry pass in-process: every registered op either
# rides a merge plane or is explicitly declared not to.
from repro.analysis.registry import check_registry
from repro.core.objclass import registered_ops

assert check_registry() == [], "objclass registry contract broken"
print(f"verification plane: registry contracts hold for "
      f"{len(registered_ops())} objclass ops "
      f"(run `python -m repro.analysis` for the full linter)")
